"""Packed tree-level chunk layout for the DeMo extractor.

The per-leaf DeMo hot path runs one DCT + top-k + inverse per pytree leaf:
N leaves -> N basis matmuls, N sorts, N gathers, N inverses, and (on a mesh)
N all-gathers. This module flattens the WHOLE momentum tree into a single
``(C_total, s)`` chunk matrix with *static* per-leaf row offsets, so the
extractor (reference jnp or the fused Pallas kernel) and the collective run
exactly once per step for the entire tree.

Layout contract (bit-compatible with per-leaf chunking):
  * each leaf is flattened, zero-padded to a multiple of the chunk size ``s``
    EXACTLY like :func:`repro.core.compression.chunk`, and contributes
    ``ceil(numel / s)`` consecutive rows starting at ``row_start``;
  * the concatenated matrix is zero-padded with trailing rows so the row
    count hits a Pallas-friendly multiple (``n_rows_padded``); trailing rows
    extract to all-zero payloads and are dropped by :func:`unpack_tree`;
  * the plan depends only on the pytree structure and leaf shapes, so it is
    identical on every replica and static under ``jit`` / ``shard_map``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Static placement of one pytree leaf inside the packed chunk matrix."""

    key: str                  # pytree key path (debugging / logging only)
    shape: tuple[int, ...]
    numel: int
    row_start: int            # first chunk row owned by this leaf
    n_rows: int               # ceil(numel / chunk_size)


@dataclasses.dataclass(frozen=True)
class PackedLayout:
    chunk_size: int
    slots: tuple[LeafSlot, ...]
    treedef: Any
    n_rows: int               # valid (leaf-owned) rows
    n_rows_padded: int        # rows after Pallas tile padding

    @property
    def n_leaves(self) -> int:
        return len(self.slots)


def _pad_rows(n_rows: int) -> int:
    """Round the row count up so the Pallas grid tiles cleanly.

    >= 128 rows: round to a multiple of 128 (the kernel tiles 128/256 rows
    per program); below that, round to the next power of two so the tile
    divisor search in the kernel wrapper still finds a large tile.
    """
    if n_rows >= 128:
        return ((n_rows + 127) // 128) * 128
    p = 1
    while p < n_rows:
        p *= 2
    return p


# Layout plans are pure functions of (treedef, leaf shapes, chunk_size), so
# they are memoized: under jit the rebuild was already free after the first
# trace, but eager callers (the N-replica simulator, benchmarks) hit
# plan_tree every step. Bounded so cached treedefs can't grow unboundedly.
_PLAN_CACHE: dict[tuple, PackedLayout] = {}
_PLAN_CACHE_MAX = 128


def plan_tree(tree, chunk_size: int) -> PackedLayout:
    """Static packed layout for ``tree`` (shapes only, no data); memoized."""
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    key = (treedef, chunk_size,
           tuple(tuple(leaf.shape) for _, leaf in paths_and_leaves))
    cached = _PLAN_CACHE.get(key)
    if cached is not None:
        return cached
    slots = []
    row = 0
    for path, leaf in paths_and_leaves:
        numel = math.prod(leaf.shape) if leaf.shape else 1
        n_rows = max(1, math.ceil(numel / chunk_size))
        slots.append(LeafSlot(key=jax.tree_util.keystr(path),
                              shape=tuple(leaf.shape), numel=numel,
                              row_start=row, n_rows=n_rows))
        row += n_rows
    if not slots:
        raise ValueError("plan_tree: empty pytree")
    layout = PackedLayout(chunk_size=chunk_size, slots=tuple(slots),
                          treedef=treedef, n_rows=row,
                          n_rows_padded=_pad_rows(row))
    if len(_PLAN_CACHE) >= _PLAN_CACHE_MAX:
        _PLAN_CACHE.clear()
    _PLAN_CACHE[key] = layout
    return layout


def pack_tree(tree, layout: PackedLayout) -> jnp.ndarray:
    """Flatten every leaf into its slot; returns f32 ``(n_rows_padded, s)``."""
    s = layout.chunk_size
    leaves = jax.tree_util.tree_leaves(tree)
    assert len(leaves) == len(layout.slots), (len(leaves), len(layout.slots))
    rows = []
    for leaf, slot in zip(leaves, layout.slots):
        flat = leaf.reshape(-1).astype(jnp.float32)
        pad = slot.n_rows * s - slot.numel
        if pad:
            flat = jnp.pad(flat, (0, pad))
        rows.append(flat.reshape(slot.n_rows, s))
    mat = jnp.concatenate(rows, axis=0)
    tail = layout.n_rows_padded - layout.n_rows
    if tail:
        mat = jnp.pad(mat, ((0, tail), (0, 0)))
    return mat


def unpack_tree(mat: jnp.ndarray, layout: PackedLayout):
    """Inverse of :func:`pack_tree` for any per-row-layout ``(C, s)`` matrix."""
    leaves = []
    for slot in layout.slots:
        rows = jax.lax.slice_in_dim(mat, slot.row_start,
                                    slot.row_start + slot.n_rows, axis=0)
        leaves.append(rows.reshape(-1)[:slot.numel].reshape(slot.shape))
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)


def slot_rows(mat: jnp.ndarray, slot: LeafSlot) -> jnp.ndarray:
    """This leaf's rows of any packed per-row tensor (chunks, vals, idx)."""
    return jax.lax.slice_in_dim(mat, slot.row_start,
                                slot.row_start + slot.n_rows, axis=0)


# ---------------------------------------------------------------------------
# leaf-group buckets: the overlap engine's unit of pipelining.
#
# A bucket is a CONTIGUOUS run of leaf slots — its rows are one slice
# [row_start, row_start + n_rows) of the packed chunk matrix, so per-bucket
# extraction/encode/decode touch disjoint row ranges and the bucketed result
# is row-for-row identical to the monolithic one (DCT, top-k, sign, and the
# codec are all row-local).  Buckets exist so each one's encoded collective
# forms an INDEPENDENT dependency chain: the scheduler can launch bucket b's
# transfer while bucket b-1's payload is still decoding (see
# replicators.base.ring_gather_decode_buckets).


DEFAULT_N_BUCKETS = 4


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One contiguous leaf group of a :class:`PackedLayout`."""

    index: int
    row_start: int            # first chunk row of the group
    n_rows: int               # valid rows (sum of member slots' n_rows)
    n_rows_padded: int        # rows after per-bucket Pallas tile padding
    slots: tuple[LeafSlot, ...]


def resolve_n_buckets(requested: int, n_leaves: int) -> int:
    """Bucket count for a tree of ``n_leaves``: ``requested`` (0 = the
    :data:`DEFAULT_N_BUCKETS` default) clamped to the leaf count — a bucket
    boundary can only sit on a leaf boundary, so a tree can never split into
    more buckets than it has leaves."""
    if requested < 0:
        raise ValueError(f"n_buckets must be >= 0, got {requested}")
    want = requested if requested else DEFAULT_N_BUCKETS
    return max(1, min(want, n_leaves))


def plan_buckets(layout: PackedLayout, n_buckets: int) -> tuple[Bucket, ...]:
    """Split ``layout``'s slots into ``n_buckets`` contiguous leaf groups.

    Boundary rule: walk the slots in packing order, closing a bucket once it
    holds at least ``ceil(remaining_rows / remaining_buckets)`` rows — a
    greedy balance that keeps per-bucket payloads within one (largest) leaf
    of each other without ever splitting a leaf across buckets.  Deriving
    boundaries from the static ``row_start`` offsets keeps the plan a pure
    function of (treedef, shapes, chunk_size, n_buckets): identical on every
    replica and static under jit/shard_map.
    """
    n_buckets = resolve_n_buckets(n_buckets, layout.n_leaves)
    buckets: list[Bucket] = []
    slots = list(layout.slots)
    i = 0
    rows_left = layout.n_rows
    for b in range(n_buckets):
        target = math.ceil(rows_left / (n_buckets - b))
        group: list[LeafSlot] = []
        rows = 0
        # leave at least one slot per remaining bucket
        while i < len(slots) and (rows < target or not group):
            if len(slots) - i <= (n_buckets - b - 1) - (0 if group else 1):
                break
            group.append(slots[i])
            rows += slots[i].n_rows
            i += 1
        buckets.append(Bucket(index=b, row_start=group[0].row_start,
                              n_rows=rows, n_rows_padded=_pad_rows(rows),
                              slots=tuple(group)))
        rows_left -= rows
    assert i == len(slots) and rows_left == 0, (i, len(slots), rows_left)
    return tuple(buckets)


def bucket_rows(mat: jnp.ndarray, bucket: Bucket,
                pad: bool = False) -> jnp.ndarray:
    """One bucket's slice of a packed per-row tensor; ``pad`` appends the
    zero rows that bring the slice to the bucket's Pallas tile padding."""
    rows = jax.lax.slice_in_dim(mat, bucket.row_start,
                                bucket.row_start + bucket.n_rows, axis=0)
    tail = bucket.n_rows_padded - bucket.n_rows
    if pad and tail:
        rows = jnp.pad(rows, ((0, tail),) + ((0, 0),) * (rows.ndim - 1))
    return rows


def plan_value_buckets(layout: ValueStreamLayout,
                       n_buckets: int) -> tuple[tuple[int, int], ...]:
    """Contiguous ``(offset, size)`` leaf-group runs of one value stream.

    The dense-scheme analogue of :func:`plan_buckets`: the same greedy
    leaf-boundary balance, over selected-value counts instead of chunk rows.
    """
    n_buckets = resolve_n_buckets(n_buckets, len(layout.sizes))
    runs: list[tuple[int, int]] = []
    i = 0
    left = layout.n_total
    n = len(layout.sizes)
    for b in range(n_buckets):
        target = math.ceil(left / (n_buckets - b))
        start = layout.offsets[i]
        size = 0
        while i < n and (size < target or size == 0):
            if n - i <= (n_buckets - b - 1) - (0 if size else 1):
                break
            size += layout.sizes[i]
            i += 1
        runs.append((start, size))
        left -= size
    assert i == n and left == 0, (i, n, left)
    return tuple(runs)


# ---------------------------------------------------------------------------
# bare value streams: the dense-scheme (random/striding/full/diloco) layout.
# No chunk rows here — the per-leaf selected values are laid end to end into
# ONE flat stream, so the whole tree rides ONE DenseCodec buffer and ONE
# collective per sync (N leaves -> 1 launch and one wire header instead of N).


@dataclasses.dataclass(frozen=True)
class ValueStreamLayout:
    """Static placement of per-leaf value runs inside one flat stream."""

    sizes: tuple[int, ...]     # per-leaf selected value counts (static)
    offsets: tuple[int, ...]   # start of each leaf's run
    n_total: int


def plan_values(sizes) -> ValueStreamLayout:
    """Layout for per-leaf value streams of the given (static) lengths."""
    sizes = tuple(int(s) for s in sizes)
    if not sizes:
        raise ValueError("plan_values: empty stream list")
    if any(s <= 0 for s in sizes):
        raise ValueError(f"plan_values: non-positive stream size in {sizes}")
    offsets, off = [], 0
    for s in sizes:
        offsets.append(off)
        off += s
    return ValueStreamLayout(sizes=sizes, offsets=tuple(offsets), n_total=off)


def pack_values(parts, layout: ValueStreamLayout) -> jnp.ndarray:
    """Concatenate per-leaf value runs into the (n_total,) f32 stream."""
    assert len(parts) == len(layout.sizes), (len(parts), len(layout.sizes))
    flat = [p.reshape(-1).astype(jnp.float32) for p in parts]
    for p, size in zip(flat, layout.sizes):
        assert p.shape == (size,), (p.shape, size)
    return jnp.concatenate(flat) if len(flat) > 1 else flat[0]


def unpack_values(stream: jnp.ndarray, layout: ValueStreamLayout):
    """Inverse of :func:`pack_values`: the per-leaf runs, in leaf order."""
    assert stream.shape == (layout.n_total,), (stream.shape, layout.n_total)
    return [jax.lax.slice_in_dim(stream, off, off + size, axis=0)
            for off, size in zip(layout.offsets, layout.sizes)]
