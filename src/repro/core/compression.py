"""Chunking, top-k extraction, and wire-payload accounting for replication schemes.

Terminology (paper):
  compression rate r  -- fraction of the full-gradient bandwidth a scheme uses.
  chunk (s)           -- DCT chunk length for the DeMo replicator.
  topk (k)            -- per-chunk number of coefficients DeMo transmits.

Wire format per scheme (per parameter shard of ``numel`` elements, per step):
  full      : numel * value_bytes
  demo      : n_chunks * k * (value_bytes + index_bytes)   (indices must travel)
  random    : n_sel   * value_bytes                        (indices reproduced from seed)
  striding  : n_sel   * value_bytes                        (indices reproduced from stride)
  diloco(n) : numel * value_bytes / n                      (full sync every n-th step)

``random``/``striding`` therefore move 2x the values of ``demo`` at equal
bandwidth when index_bytes == value_bytes (the paper's "double the amount of
data, on the same bandwidth").

DeMo wire format, precisely: per chunk row, ``k`` coefficient VALUES
(optionally sign-compressed to {-1, 0, +1} before the collective) plus ``k``
integer INDICES — wire format v2 serializes the in-chunk position ``j`` only
(the row is implied by buffer position), so indices stay uint16 whenever the
chunk fits (``s <= 65536``) regardless of tree size; the legacy v1 layout
(global flat positions ``row*s + j``, uint16 only while ``C_total*s`` fits)
still decodes via the version byte. Indices differ per replica, so they must
travel. The packed tree-level path (``repro.core.packing``) concatenates
every leaf's chunk rows into one ``(C_total, s)`` matrix with static
offsets; the payload for the whole tree is then a single ``(C_total, k)``
pair of values/indices, serialized by ``repro.comms.codecs`` into ONE
contiguous versioned buffer (fp32/bf16/int8 amplitudes) and shipped with ONE
fixed-shape ``all_gather`` instead of one per leaf. Zero-padded layout rows
extract to zero values and are sliced off before encode, so they never
travel.

The codec is the ONLY wire path: the per-leaf DeMo reference and the
masked/dense schemes (random / striding / full / diloco) also serialize
their payloads (``codecs.PackedCodec`` per leaf, ``codecs.DenseCodec`` value
streams), so the ``wire_bytes`` every replicator reports is the byte length
of an encoded buffer. The byte formulas below are the PLANNING model for the
``codec="off"`` escape hatch (raw f32 collectives) and the bandwidth-rate
arithmetic (``rate_to_topk``); the ``repro.comms.planner`` budget search
prices codec-on candidates with the codec's own static sizing instead.

Extractor implementations (``FlexConfig.extract_impl``):
  per_leaf          -- dense jnp reference, one extraction per pytree leaf
                       (the seed behaviour; baseline for the benchmarks).
  packed            -- dense jnp reference over the packed (C_total, s)
                       matrix: one extraction + one collective per TREE.
  pallas            -- packed layout + the fused Pallas extract/decode
                       kernels (TPU compile target).
  pallas_interpret  -- same kernels in interpreter mode (CPU CI).
  auto (default)    -- "pallas" on TPU backends, "packed" elsewhere.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import dct


# ---------------------------------------------------------------------------
# chunking


def pad_to_multiple(x: jnp.ndarray, multiple: int) -> jnp.ndarray:
    n = x.size
    pad = (-n) % multiple
    flat = x.reshape(-1)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat


def chunk(x: jnp.ndarray, chunk_size: int) -> jnp.ndarray:
    """Flatten ``x`` and reshape to (n_chunks, chunk_size), zero-padded."""
    flat = pad_to_multiple(x, chunk_size)
    return flat.reshape(-1, chunk_size)


def unchunk(chunks: jnp.ndarray, shape: tuple[int, ...]) -> jnp.ndarray:
    n = math.prod(shape) if shape else 1
    return chunks.reshape(-1)[:n].reshape(shape)


# ---------------------------------------------------------------------------
# top-k in the DCT domain (the DeMo extractor)


def dct_topk_extract(
    m: jnp.ndarray, chunk_size: int, k: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """DeMo's ExtractFastComponents on a single tensor.

    Returns ``(values, indices, q)`` where ``values/indices`` are the per-chunk
    top-|k| DCT-II coefficients (shape (n_chunks, k)) -- the wire payload -- and
    ``q`` is the decoded (time-domain) extracted component with ``m``'s shape,
    i.e. what must be subtracted from the local momentum.
    """
    c = chunk(m, chunk_size)                      # (C, s)
    basis = dct.dct_basis(chunk_size, c.dtype)
    coeff = c @ basis.T                           # DCT-II
    mag = jnp.abs(coeff)
    _, idx = jax.lax.top_k(mag, k)                # (C, k)
    vals = jnp.take_along_axis(coeff, idx, axis=-1)
    q = decode_dct_topk(vals, idx, chunk_size, m.shape)
    return vals, idx, q


def decode_dct_topk(
    vals: jnp.ndarray, idx: jnp.ndarray, chunk_size: int, shape: tuple[int, ...]
) -> jnp.ndarray:
    """Scatter the top-k coefficients back into chunks and inverse-DCT."""
    n_chunks = vals.shape[0]
    coeff = jnp.zeros((n_chunks, chunk_size), vals.dtype)
    coeff = jnp.put_along_axis(coeff, idx, vals, axis=-1, inplace=False)
    basis = dct.dct_basis(chunk_size, vals.dtype)
    return unchunk(coeff @ basis, shape)


# ---------------------------------------------------------------------------
# packed (tree-level) extraction: one call for a whole chunk-row matrix

EXTRACT_IMPLS = ("per_leaf", "packed", "pallas", "pallas_interpret", "auto")


def resolve_extract_impl(impl: str) -> str:
    """Resolve ``auto`` against the runtime backend; validate the rest."""
    if impl not in EXTRACT_IMPLS:
        raise ValueError(f"unknown extract_impl {impl!r}; have {EXTRACT_IMPLS}")
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "packed"
    return impl


def packed_dct_topk(
    chunks: jnp.ndarray, k: int, impl: str = "packed"
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Top-k DCT extraction over pre-packed chunk rows, one call per tree.

    chunks: (C, s). Returns (vals (C,k), idx (C,k) i32, q_rows (C,s)) where
    ``q_rows`` is the decoded extracted component in chunk-row layout.
    Row-wise identical to running :func:`dct_topk_extract` on each leaf.
    """
    impl = resolve_extract_impl(impl)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.dct_topk.ops import dct_topk_packed

        return dct_topk_packed(chunks, k, interpret=impl == "pallas_interpret")
    s = chunks.shape[-1]
    basis = dct.dct_basis(s, jnp.float32)
    coeff = chunks.astype(jnp.float32) @ basis.T
    _, idx = jax.lax.top_k(jnp.abs(coeff), k)
    vals = jnp.take_along_axis(coeff, idx, axis=-1)
    return vals, idx.astype(jnp.int32), decode_dct_topk(vals, idx, s,
                                                        chunks.shape)


def accumulate_coeff(
    acc: jnp.ndarray, vals: jnp.ndarray, idx: jnp.ndarray
) -> jnp.ndarray:
    """Scatter-add ONE replica's (C, k) payload into a dense (C, s) coefficient
    accumulator — the per-hop decode step of the streaming ring transport.
    Folding every replica's payload with this and then applying
    ``(acc / |R|) @ dct_basis`` reproduces :func:`decode_gathered_ref`
    without ever materializing the gathered (|R|, C, k) stack.
    """
    c = vals.shape[0]
    rows = jnp.broadcast_to(jnp.arange(c)[:, None], idx.shape)
    return acc.at[rows.reshape(-1), idx.reshape(-1)].add(
        vals.reshape(-1).astype(jnp.float32))


def coeff_mean_idct(acc: jnp.ndarray, n_rep: int, chunk_size: int) -> jnp.ndarray:
    """(C, s) accumulated coefficients -> replica-mean decoded chunk rows."""
    return (acc / n_rep) @ dct.dct_basis(chunk_size, jnp.float32)


def decode_gathered_ref(
    g_vals: jnp.ndarray, g_idx: jnp.ndarray, chunk_size: int
) -> jnp.ndarray:
    """Reference decode of gathered payloads (R, C, k) -> mean q rows (C, s).

    Scatter-adds every replica's coefficients (duplicates accumulate), then
    averages and inverse-transforms; the jnp oracle for the fused Pallas
    decode kernel in ``repro.kernels.dct_topk.decode``.
    """
    n_rep, c, _ = g_vals.shape
    coeff = jnp.zeros((c, chunk_size), jnp.float32)
    rows = jnp.broadcast_to(jnp.arange(c)[None, :, None], g_idx.shape)
    coeff = coeff.at[rows.reshape(-1), g_idx.reshape(-1)].add(
        g_vals.reshape(-1).astype(jnp.float32))
    coeff = coeff / n_rep
    return coeff @ dct.dct_basis(chunk_size, jnp.float32)


# ---------------------------------------------------------------------------
# index masks for seeded schemes


def rate_to_stride(rate: float) -> int:
    """Stride (and diloco period) for a target rate — shared by
    ``FlexConfig.make`` and the planner so predicted bytes match actual."""
    return max(1, int(round(1 / rate)))


def random_n_sel(numel: int, rate: float) -> int:
    """Selected-element count of the random scheme (single source of truth
    for the replicator AND the planner, so predicted bytes match actual)."""
    return max(1, int(round(numel * rate)))


def striding_n_sel(numel: int, stride: int) -> int:
    """Selected-element count of the striding scheme (shared with planner)."""
    return math.ceil(numel / stride)


def random_mask(shape: tuple[int, ...], rate: float, seed, step) -> jnp.ndarray:
    """Bernoulli(rate) mask, reproducible from (seed, step) on every replica."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    return jax.random.bernoulli(key, rate, shape)


def striding_mask(shape: tuple[int, ...], stride: int, step) -> jnp.ndarray:
    """Every ``stride``-th element; the offset rotates with the step."""
    n = math.prod(shape) if shape else 1
    offset = step % stride
    return ((jnp.arange(n) % stride) == offset).reshape(shape)


# ---------------------------------------------------------------------------
# wire accounting


@dataclasses.dataclass(frozen=True)
class WireFormat:
    value_bytes: int = 4   # fp32 payload (paper's dtype study: fp32 > bf16/fp16)
    index_bytes: int = 2   # uint16 suffices for chunk <= 65536


def rate_to_topk(rate: float, chunk_size: int, wire: WireFormat = WireFormat()) -> int:
    """DeMo top-k that matches a target bandwidth ``rate`` (vs full fp32 sync)."""
    per_coeff = wire.value_bytes + wire.index_bytes
    k = int(round(rate * chunk_size * wire.value_bytes / per_coeff))
    return max(1, min(chunk_size, k))


def demo_wire_bytes(numel: int, chunk_size: int, k: int, wire: WireFormat = WireFormat()) -> int:
    n_chunks = math.ceil(numel / chunk_size)
    return n_chunks * k * (wire.value_bytes + wire.index_bytes)


def masked_wire_bytes(numel: int, rate: float, wire: WireFormat = WireFormat()) -> int:
    return int(math.ceil(numel * rate)) * wire.value_bytes


def full_wire_bytes(numel: int, wire: WireFormat = WireFormat()) -> int:
    return numel * wire.value_bytes
