"""Chunking, top-k extraction, and wire-payload accounting for replication schemes.

Terminology (paper):
  compression rate r  -- fraction of the full-gradient bandwidth a scheme uses.
  chunk (s)           -- DCT chunk length for the DeMo replicator.
  topk (k)            -- per-chunk number of coefficients DeMo transmits.

Wire format per scheme (per parameter shard of ``numel`` elements, per step):
  full      : numel * value_bytes
  demo      : n_chunks * k * (value_bytes + index_bytes)   (indices must travel)
  random    : n_sel   * value_bytes                        (indices reproduced from seed)
  striding  : n_sel   * value_bytes                        (indices reproduced from stride)
  diloco(n) : numel * value_bytes / n                      (full sync every n-th step)

``random``/``striding`` therefore move 2x the values of ``demo`` at equal
bandwidth when index_bytes == value_bytes (the paper's "double the amount of
data, on the same bandwidth").
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import dct


# ---------------------------------------------------------------------------
# chunking


def pad_to_multiple(x: jnp.ndarray, multiple: int) -> jnp.ndarray:
    n = x.size
    pad = (-n) % multiple
    flat = x.reshape(-1)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat


def chunk(x: jnp.ndarray, chunk_size: int) -> jnp.ndarray:
    """Flatten ``x`` and reshape to (n_chunks, chunk_size), zero-padded."""
    flat = pad_to_multiple(x, chunk_size)
    return flat.reshape(-1, chunk_size)


def unchunk(chunks: jnp.ndarray, shape: tuple[int, ...]) -> jnp.ndarray:
    n = math.prod(shape) if shape else 1
    return chunks.reshape(-1)[:n].reshape(shape)


# ---------------------------------------------------------------------------
# top-k in the DCT domain (the DeMo extractor)


def dct_topk_extract(
    m: jnp.ndarray, chunk_size: int, k: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """DeMo's ExtractFastComponents on a single tensor.

    Returns ``(values, indices, q)`` where ``values/indices`` are the per-chunk
    top-|k| DCT-II coefficients (shape (n_chunks, k)) -- the wire payload -- and
    ``q`` is the decoded (time-domain) extracted component with ``m``'s shape,
    i.e. what must be subtracted from the local momentum.
    """
    c = chunk(m, chunk_size)                      # (C, s)
    basis = dct.dct_basis(chunk_size, c.dtype)
    coeff = c @ basis.T                           # DCT-II
    mag = jnp.abs(coeff)
    _, idx = jax.lax.top_k(mag, k)                # (C, k)
    vals = jnp.take_along_axis(coeff, idx, axis=-1)
    q = decode_dct_topk(vals, idx, chunk_size, m.shape)
    return vals, idx, q


def decode_dct_topk(
    vals: jnp.ndarray, idx: jnp.ndarray, chunk_size: int, shape: tuple[int, ...]
) -> jnp.ndarray:
    """Scatter the top-k coefficients back into chunks and inverse-DCT."""
    n_chunks = vals.shape[0]
    coeff = jnp.zeros((n_chunks, chunk_size), vals.dtype)
    coeff = jnp.put_along_axis(coeff, idx, vals, axis=-1, inplace=False)
    basis = dct.dct_basis(chunk_size, vals.dtype)
    return unchunk(coeff @ basis, shape)


# ---------------------------------------------------------------------------
# index masks for seeded schemes


def random_mask(shape: tuple[int, ...], rate: float, seed, step) -> jnp.ndarray:
    """Bernoulli(rate) mask, reproducible from (seed, step) on every replica."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    return jax.random.bernoulli(key, rate, shape)


def striding_mask(shape: tuple[int, ...], stride: int, step) -> jnp.ndarray:
    """Every ``stride``-th element; the offset rotates with the step."""
    n = math.prod(shape) if shape else 1
    offset = step % stride
    return ((jnp.arange(n) % stride) == offset).reshape(shape)


# ---------------------------------------------------------------------------
# wire accounting


@dataclasses.dataclass(frozen=True)
class WireFormat:
    value_bytes: int = 4   # fp32 payload (paper's dtype study: fp32 > bf16/fp16)
    index_bytes: int = 2   # uint16 suffices for chunk <= 65536


def rate_to_topk(rate: float, chunk_size: int, wire: WireFormat = WireFormat()) -> int:
    """DeMo top-k that matches a target bandwidth ``rate`` (vs full fp32 sync)."""
    per_coeff = wire.value_bytes + wire.index_bytes
    k = int(round(rate * chunk_size * wire.value_bytes / per_coeff))
    return max(1, min(chunk_size, k))


def demo_wire_bytes(numel: int, chunk_size: int, k: int, wire: WireFormat = WireFormat()) -> int:
    n_chunks = math.ceil(numel / chunk_size)
    return n_chunks * k * (wire.value_bytes + wire.index_bytes)


def masked_wire_bytes(numel: int, rate: float, wire: WireFormat = WireFormat()) -> int:
    return int(math.ceil(numel * rate)) * wire.value_bytes


def full_wire_bytes(numel: int, wire: WireFormat = WireFormat()) -> int:
    return numel * wire.value_bytes
