"""End-to-end distributed training driver: hybrid-sharded FlexDeMo on a
(data x model) mesh of 8 simulated devices, with logging, eval, and
checkpointing — the same code path the production mesh uses.

  PYTHONPATH=src python examples/train_distributed.py --steps 100
  PYTHONPATH=src python examples/train_distributed.py --preset 100m --steps 300

(CPU note: the 100m preset is faithful but slow on a laptop CPU; the default
preset is a ~2M-param model that finishes a few hundred steps in minutes.)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt
from repro.configs import get_config
from repro.core import FlexConfig, make_optimizer
from repro.data.synthetic import BigramLM
from repro.launch.mesh import make_mesh
from repro.training import schedules
from repro.training.state import init_state, make_train_plan
from repro.training.step import build_train_step

PRESETS = {
    "tiny": dict(d_model=192, n_layers=4, vocab=2048, batch=8, seq=128),
    "20m": dict(d_model=512, n_layers=6, vocab=8192, batch=8, seq=256),
    "100m": dict(d_model=768, n_layers=12, vocab=32768, batch=16, seq=512),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--scheme", default="demo")
    ap.add_argument("--rate", type=float, default=1 / 16)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = get_config("olmo2-1b").reduced(
        n_layers=p["n_layers"], d_model=p["d_model"], vocab=p["vocab"],
        d_ff=p["d_model"] * 4)
    n_par = None
    mesh = make_mesh((2, 4), ("data", "model"))
    opt = make_optimizer(
        "demo_sgd", schedules.warmup_cosine(args.lr, args.steps),
        FlexConfig(scheme=args.scheme, rate=args.rate), momentum_decay=0.95)
    plan = make_train_plan(cfg, mesh, p["batch"], p["seq"])
    print(f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} | "
          f"S={plan.fsdp_axes} R={plan.repl_axes} batch_axes={plan.batch_axes}")

    step, shardings, _ = build_train_step(cfg, mesh, opt, plan)
    state = init_state(jax.random.PRNGKey(0), cfg, opt, plan)
    n_par = sum(int(np.prod(l.shape)) for l in
                jax.tree_util.tree_leaves(state["params"]))
    print(f"arch {cfg.name}: {n_par/1e6:.1f}M params, "
          f"scheme {args.scheme}@{args.rate:g}")

    stream = BigramLM(cfg.vocab_size, p["seq"], p["batch"], seed=0)
    t0 = time.perf_counter()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
        state, m = step(state, batch)
        if (i + 1) % 10 == 0:
            dt = (time.perf_counter() - t0) / (i + 1)
            print(f"step {i+1:4d} loss {float(m['loss']):.4f} "
                  f"({dt:.2f}s/step, wire {float(m['wire_bytes']):,.0f} B)")
    if args.ckpt_dir:
        ckpt.save(os.path.join(args.ckpt_dir, f"ckpt_{args.steps}"),
                  jax.device_get(state), step=args.steps)
        print("checkpoint saved to", args.ckpt_dir)


if __name__ == "__main__":
    main()
