"""Distributed serving demo: prefill a prompt, then batched decode with the
flash-decode (seq-sharded KV cache) engine on 8 simulated devices.

  PYTHONPATH=src python examples/serve_demo.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import init_model, transformer
from repro.serving.engine import build_serve_step, make_serve_plan


def main():
    cfg = get_config("qwen2.5-3b").reduced(n_layers=4, d_model=256, vocab=512)
    mesh = make_mesh((2, 4), ("data", "model"))
    B, MAXLEN, DECODE = 4, 64, 24
    plan = make_serve_plan(cfg, mesh, B, MAXLEN)
    step, shardings, specs, state_shapes, st_ps = build_serve_step(
        cfg, mesh, plan, donate=False)
    params = init_model(jax.random.PRNGKey(0), cfg)
    state = transformer.init_decode_state(cfg, B, plan.max_len)

    key = jax.random.PRNGKey(7)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    outs = []
    t0 = time.perf_counter()
    for t in range(DECODE):
        logits, state = step(params, state, tok, jnp.asarray(t, jnp.int32))
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        outs.append(np.asarray(tok[:, 0]))
    dt = time.perf_counter() - t0
    print(f"decoded {DECODE} tokens x {B} sequences on "
          f"{len(jax.devices())} devices in {dt:.2f}s "
          f"({1e3*dt/DECODE:.1f} ms/token)")
    print("sampled ids:", np.stack(outs, 1)[0][:12], "...")


if __name__ == "__main__":
    main()
