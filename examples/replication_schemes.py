"""Paper Fig. 1/2 in miniature: compare every DeToNATION replication scheme
(demo / random / striding / diloco / full) at equal modeled bandwidth on the
seq2seq translation surrogate, with 2 decoupled replicas.

  PYTHONPATH=src python examples/replication_schemes.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.common import train_replicated
from repro.configs import get_config
from repro.core import FlexConfig
from repro.data.synthetic import Seq2Seq


def main():
    cfg = get_config("t5-repro").reduced(n_layers=2, d_model=64, vocab=64)
    stream = Seq2Seq(64, 12, 8, seed=0)
    print(f"{'scheme':10s} {'val loss':>9s} {'train':>8s} {'bytes/step':>12s}")
    for scheme in ("demo", "random", "striding", "diloco", "full"):
        res = train_replicated(cfg, FlexConfig(scheme=scheme, rate=1 / 8),
                               stream, n_steps=80, lr=0.01, eval_every=20)
        print(f"{scheme:10s} {res.final_val():9.4f} "
              f"{np.mean(res.train_losses[-5:]):8.4f} "
              f"{res.wire_bytes:12,.0f}")
    print("\n(equal-bandwidth comparison; the paper finds random best for "
          "seq2seq, demo best for vision/causal-LM)")


if __name__ == "__main__":
    main()
