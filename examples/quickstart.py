"""Quickstart: train a tiny causal LM with FlexDeMo (DeMo replication) and
compare against the conventional full-sync AdamW baseline — single device,
~1 minute on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import FlexConfig, apply_updates, make_optimizer
from repro.data.synthetic import BigramLM
from repro.models import init_model, loss_fn
from repro.training.loop import run


def make_step(cfg, opt):
    @jax.jit
    def step_fn(state, batch):
        (loss, m), g = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True)(state["params"])
        upd, opt_state, aux = opt.update(g, state["opt"], state["params"],
                                         axes=())
        return ({"params": apply_updates(state["params"], upd),
                 "opt": opt_state, "step": state["step"] + 1},
                {"loss": loss,
                 "wire_bytes": jnp.asarray(aux.wire_bytes, jnp.float32)})

    return step_fn


def main():
    cfg = get_config("olmo2-1b").reduced(n_layers=2, d_model=128, vocab=128)
    stream = BigramLM(cfg.vocab_size, 64, 8, seed=0)

    results = {}
    for name, opt in [
        ("flexdemo(demo@1/16)", make_optimizer(
            "demo_sgd", 0.01, FlexConfig(scheme="demo", rate=1 / 16),
            momentum_decay=0.9)),
        ("hybrid-fsdp(adamw, full sync)", make_optimizer("adamw", 3e-3)),
    ]:
        params = init_model(jax.random.PRNGKey(0), cfg)
        state = {"params": params, "opt": opt.init(params),
                 "step": jnp.zeros((), jnp.int32)}
        state, res = run(make_step(cfg, opt), state, stream, n_steps=80,
                         log_every=20, log=lambda s: print(f"[{name}] {s}"))
        results[name] = res

    # modeled wire for the full-sync baseline (adamw reports 0 with axes=())
    from repro.core.flexdemo import tree_wire_bytes
    from repro.core.replicators import make_replicator

    full_wire = tree_wire_bytes(make_replicator("full"),
                                init_model(jax.random.PRNGKey(0), cfg))

    print("\n=== summary (tiny CPU run) ===")
    for name, res in results.items():
        import numpy as np

        wire = res.wire_bytes_per_step or full_wire
        print(f"{name:32s} final loss {np.mean(res.train_losses[-5:]):.4f} "
              f"inter-node bytes/step {wire:,.0f}")
    print("\nFlexDeMo reaches a comparable loss while moving a fraction of "
          "the bytes between nodes — the paper's headline result.")


if __name__ == "__main__":
    main()
