#!/usr/bin/env python
"""Convergence gate: compare fresh ``run_convergence.py`` trajectories
against the committed baselines under ``experiments/convergence/``.

Three classes of check, per domain file (rows matched by ``setting``):

  * exact      -- rows whose BASELINE marks ``deterministic`` (fp32
                  amplitudes + sign payloads: the ternary ring fold is exact
                  in any order) must reproduce the committed train/val
                  trajectory on the overlapping step prefix.  ``--exact-tol``
                  (relative, default 0 = bit-exact) exists solely to absorb
                  cross-machine float codegen differences on CI runners.
                  ``wire_bytes_per_step`` is exact for EVERY row, always —
                  wire formats are static functions of shapes and codecs.
  * tolerance  -- when the current run is full-length, every row's final
                  train/val loss must stay within ``--loss-tol`` (relative)
                  of its baseline, and its final-loss ratio vs the AdamW
                  full-sync reference must not drift by more than
                  ``--loss-tol`` either.
  * parity     -- the paper-parity acceptance: every ``flexdemo`` row must
                  satisfy ``final_val <= (1 + eps) * final_val(reference)``.
                  Checked on the COMMITTED baselines every run (a refresh
                  that regresses parity cannot ship) and on the current run
                  when it is full-length.

A ``--smoke`` current run (shorter step budget) is a strict PREFIX of the
full trajectory (constant lr, (seed, step)-pure streams), so the exact
checks still bite; the final-loss checks only apply at full length.

Usage:
  python scripts/check_convergence.py CURRENT_DIR_OR_FILE
      [--baseline-dir experiments/convergence] [--exact-tol 0]
      [--loss-tol 0.25] [--parity-eps 0.1] [--update]

``--update`` rewrites the baseline files from CURRENT instead of comparing.

Exit status: 0 = no regressions, 1 = at least one regression (printed),
2 = usage / missing or malformed input.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


class CheckError(Exception):
    """Malformed input (usage error, exit 2) — never a traceback."""


def _rel(a: float, b: float) -> float:
    return abs(a - b) / max(abs(b), 1e-12)


def _load_json(path: str) -> dict:
    try:
        with open(path) as f:
            data = json.load(f)
    except OSError as e:
        raise CheckError(f"{path}: cannot read ({e})")
    except json.JSONDecodeError as e:
        raise CheckError(f"{path}: not valid JSON ({e})")
    if not isinstance(data, dict) or "domain" not in data \
            or "rows" not in data:
        raise CheckError(f"{path}: expected a run_convergence.py payload "
                         "with 'domain' and 'rows' fields")
    return data


def load_current(path: str) -> dict:
    """{domain: payload} from a run_convergence.py output dir or file."""
    if os.path.isdir(path):
        files = sorted(glob.glob(os.path.join(path, "*.json")))
        if not files:
            raise CheckError(f"{path}: no *.json trajectory files inside")
    else:
        files = [path]
    out = {}
    for f in files:
        data = _load_json(f)
        out[data["domain"]] = data
    return out


def _check_parity(tag: str, rows: list, eps: float,
                  failures: list[str]) -> None:
    ref = next((r for r in rows if r.get("reference")), None)
    if ref is None:
        failures.append(f"{tag}: no reference (AdamW full-sync) row — "
                        "parity cannot be checked")
        return
    ref_val = ref.get("final_val")
    if not isinstance(ref_val, (int, float)):
        failures.append(f"{tag}[{ref.get('setting')}]: reference row lacks "
                        "a numeric final_val — parity cannot be checked")
        return
    for r in rows:
        if not r.get("flexdemo"):
            continue
        val = r.get("final_val")
        if not isinstance(val, (int, float)):
            failures.append(f"{tag}[{r.get('setting')}]: flexdemo row lacks "
                            "a numeric final_val — parity cannot be checked")
            continue
        if not (val <= (1.0 + eps) * ref_val):
            failures.append(
                f"{tag}[{r.get('setting')}]: paper-parity violated — "
                f"final_val {val:.4f} > (1+{eps:g}) x reference "
                f"{ref_val:.4f}")


def _check_trajectory(tag: str, cur: dict, base: dict, exact_tol: float,
                      failures: list[str]) -> None:
    for field in ("train_losses",):
        c, b = cur.get(field) or [], base.get(field) or []
        n = min(len(c), len(b))
        if n == 0:
            failures.append(f"{tag}.{field}: empty trajectory")
            continue
        for i in range(n):
            if _rel(c[i], b[i]) > exact_tol:
                failures.append(
                    f"{tag}.{field}[{i}]: deterministic trajectory drifted "
                    f"{b[i]!r} -> {c[i]!r} (exact check, tol {exact_tol:g}; "
                    "refresh baselines with --update if intentional)")
                break
    bvals = {int(s): v for s, v in base.get("val_losses") or []}
    for s, v in cur.get("val_losses") or []:
        bv = bvals.get(int(s))
        if bv is not None and _rel(v, bv) > exact_tol:
            failures.append(
                f"{tag}.val_losses[step {s}]: deterministic eval loss "
                f"drifted {bv!r} -> {v!r} (exact check, tol {exact_tol:g})")
            break


def compare_domain(domain: str, cur: dict, base: dict, exact_tol: float,
                   loss_tol: float, parity_eps: float) -> list[str]:
    failures: list[str] = []
    ccfg = {k: v for k, v in (cur.get("config") or {}).items()
            if k != "steps"}
    bcfg = {k: v for k, v in (base.get("config") or {}).items()
            if k != "steps"}
    if ccfg != bcfg:
        diff = sorted(k for k in set(ccfg) | set(bcfg)
                      if ccfg.get(k) != bcfg.get(k))
        failures.append(
            f"{domain}.config: workload changed ({', '.join(diff)}) — "
            "trajectories are not comparable; refresh baselines with "
            "--update if intentional")
        return failures
    crows = {r.get("setting"): r for r in cur.get("rows", [])}
    brows = {r.get("setting"): r for r in base.get("rows", [])}
    base_steps = (base.get("config") or {}).get("steps")
    full_length = bool(crows) and all(r.get("steps") == base_steps
                                      for r in crows.values())
    for name, brow in brows.items():
        crow = crows.get(name)
        tag = f"{domain}[{name}]"
        if crow is None:
            failures.append(f"{tag}: row disappeared from the run")
            continue
        # wire bytes are static functions of shapes x codec: exact, always
        if float(crow.get("wire_bytes_per_step", -1.0)) != \
                float(brow.get("wire_bytes_per_step", -1.0)):
            failures.append(
                f"{tag}.wire_bytes_per_step: "
                f"{brow.get('wire_bytes_per_step')} -> "
                f"{crow.get('wire_bytes_per_step')} (exact check)")
        if brow.get("deterministic"):
            _check_trajectory(tag, crow, brow, exact_tol, failures)
        if brow.get("faults"):
            # a fault row that ran the pristine transport (zero degraded
            # hops) silently stopped testing anything — gate the counter
            counter = ("fault_hops_dropped"
                       if brow.get("on_straggler") == "skip"
                       else "fault_hops_stale")
            cv = crow.get(counter)
            if not isinstance(cv, (int, float)) or not cv > 0:
                failures.append(
                    f"{tag}.{counter}: expected > 0 for a fault-injected "
                    f"row, got {cv!r} — the degraded transport never "
                    "engaged")
        if full_length:
            for field in ("final_train", "final_val",
                          "final_val_ratio_vs_ref"):
                cv, bv = crow.get(field), brow.get(field)
                if not isinstance(bv, (int, float)):
                    continue
                if not isinstance(cv, (int, float)) \
                        or _rel(cv, bv) > loss_tol:
                    failures.append(
                        f"{tag}.{field}: {bv!r} -> {cv!r} exceeds the "
                        f"{loss_tol:g} relative tolerance band")
    # the parity criterion must hold on the COMMITTED baselines every run,
    # and on the current run whenever it trained to full length
    _check_parity(f"{domain}(baseline)", list(brows.values()), parity_eps,
                  failures)
    if full_length:
        _check_parity(f"{domain}(current)", list(crows.values()), parity_eps,
                      failures)
    return failures


def run_check(current_path: str, baseline_dir: str, exact_tol: float,
              loss_tol: float, parity_eps: float,
              update: bool = False) -> list[str]:
    current = load_current(current_path)
    if update:
        os.makedirs(baseline_dir, exist_ok=True)
        for domain, data in current.items():
            path = os.path.join(baseline_dir, f"{domain}.json")
            with open(path, "w") as f:
                json.dump(data, f, indent=1)
            print(f"updated baseline {domain}.json "
                  f"({len(data.get('rows', []))} rows)")
        return []
    failures: list[str] = []
    checked = 0
    for domain, data in sorted(current.items()):
        bpath = os.path.join(baseline_dir, f"{domain}.json")
        if not os.path.exists(bpath):
            failures.append(
                f"{domain}: no committed baseline at {bpath} — run "
                "scripts/run_convergence.py and commit via --update")
            continue
        baseline = _load_json(bpath)
        failures += compare_domain(domain, data, baseline, exact_tol,
                                   loss_tol, parity_eps)
        checked += 1
    if checked == 0 and not failures:
        failures.append(f"no baselines under {baseline_dir!r} matched "
                        f"{sorted(current)} — nothing was actually checked")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("current",
                    help="dir (or single file) written by run_convergence.py")
    ap.add_argument("--baseline-dir", default="experiments/convergence")
    ap.add_argument("--exact-tol", type=float, default=0.0,
                    help="relative tolerance for the deterministic "
                         "trajectory checks (0 = bit-exact; CI passes a "
                         "tiny value to absorb cross-runner float codegen)")
    ap.add_argument("--loss-tol", type=float, default=0.25,
                    help="relative band on final losses / vs-ref ratios "
                         "for full-length runs")
    ap.add_argument("--parity-eps", type=float, default=0.1,
                    help="paper-parity slack: flexdemo final_val must be "
                         "<= (1+eps) x the AdamW full-sync reference")
    ap.add_argument("--update", action="store_true",
                    help="rewrite baselines from CURRENT instead of "
                         "comparing")
    args = ap.parse_args(argv)

    if not os.path.exists(args.current):
        print(f"error: {args.current} not found", file=sys.stderr)
        return 2
    try:
        failures = run_check(args.current, args.baseline_dir,
                             args.exact_tol, args.loss_tol,
                             args.parity_eps, args.update)
    except CheckError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if failures:
        print(f"CONVERGENCE REGRESSION: {len(failures)} check(s) failed")
        for f in failures:
            print(f"  FAIL {f}")
        return 1
    if not args.update:
        print("convergence gate: OK (deterministic trajectories exact, "
              "loss bands within tolerance, paper parity holds)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
