#!/usr/bin/env python
"""Predicted-vs-measured planner drift report over telemetry JSONLs.

Joins each run's recorded per-step telemetry (``--telemetry-out`` of
``repro.launch.train`` / ``scripts/run_convergence.py``) against the planner
prediction its manifest carries (``comm_plan``: a ``CommPlan`` priced on the
run's LOCAL momentum shard numels) and reports, per file:

  wire_ratio           predicted / measured wire bytes per step.  Both sides
                       are static codec byte counts of the same shard sizing
                       on the same per-step accounting basis (the plan's
                       ``wire_bytes_per_step``: diloco's sync burst amortized
                       over its period, the plain wire bytes elsewhere), so
                       this is EXACTLY 1.0 whenever planner and replicator
                       serialization agree — ``--check`` enforces it.
  comm_vs_wall         predicted serialized-ring sync seconds / measured
                       median step wall seconds
  ring_vs_wall         predicted streaming-ring seconds / measured wall
  overlapped_vs_wall   predicted bucketed-engine exposed seconds / wall
  block_vs_wall        measured: median device-block share of the step
  exposed_sync_est_s   measured: median block_s minus min block_s (compute is
                       constant per step; what varies is exposed sync)

Time ratios are diagnostics, not gates: the committed runs execute on
simulated fake devices, so predicted seconds model a REAL cluster while the
measured wall is host-bound — the report requires them finite, not close.
When the manifest carries a ``codec_calibration`` block, the run's own
measured encode/decode throughput is echoed as a
``topology.overhead_from_telemetry``-ready calibration source.

  python scripts/report_drift.py /tmp/conv_telemetry/*.jsonl --check
  python scripts/report_drift.py run.jsonl --json /tmp/drift.json
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.telemetry.record import _median           # noqa: E402
from repro.telemetry.sinks import read_jsonl         # noqa: E402


def analyze(path: str, skip: int = 1) -> dict:
    """The drift record for one telemetry JSONL."""
    events = read_jsonl(path)
    manifest = next((e for e in events if e.get("event") == "manifest"), None)
    steps = [e for e in events if e.get("event") == "step"]
    if manifest is None or not steps:
        raise ValueError(f"{path}: no manifest/step events "
                         f"({len(events)} events)")
    timed = steps[skip:] or steps       # drop compile-bearing warmup steps
    wall = _median([s["wall_s"] for s in timed])
    block = _median([s["block_s"] for s in timed])
    block_min = min(s["block_s"] for s in timed)
    measured_wire = steps[-1]["wire_bytes"]

    rec = {
        "file": path,
        "setting": manifest.get("setting"),
        "domain": manifest.get("domain"),
        "config": manifest.get("config"),
        "n_steps": len(steps),
        "skip": skip,
        "measured": {
            "wire_bytes_per_step": measured_wire,
            "wall_s_median": wall,
            "block_s_median": block,
            "block_vs_wall": block / wall if wall > 0 else float("inf"),
            "exposed_sync_est_s": block - block_min,
        },
    }
    plan = manifest.get("comm_plan")
    if plan is not None:
        measured = measured_wire or float("nan")
        wall_den = wall if wall > 0 else float("nan")
        # the join basis: the plan's prediction on the replicator's per-step
        # accounting (diloco's sync burst amortized over its period; equal
        # to wire_bytes for every other scheme)
        predicted_wire = plan.get("wire_bytes_per_step", plan["wire_bytes"])
        rec["predicted"] = {
            "wire_bytes": predicted_wire,
            "wire_bytes_burst": plan["wire_bytes"],
            "comm_seconds": plan["comm_seconds"],
            "comm_seconds_pipelined": plan["comm_seconds_pipelined"],
            "comm_seconds_overlapped": plan["comm_seconds_overlapped"],
            "link": plan["link"],
            "n_replicas": plan["n_replicas"],
        }
        rec["ratios"] = {
            "wire_ratio": predicted_wire / measured,
            "comm_vs_wall": plan["comm_seconds"] / wall_den,
            "ring_vs_wall": plan["comm_seconds_pipelined"] / wall_den,
            "overlapped_vs_wall": plan["comm_seconds_overlapped"] / wall_den,
        }
    cal = manifest.get("codec_calibration")
    if cal:
        rec["calibration"] = {
            "encode_MBps": cal["encode_MBps"],
            "decode_MBps": cal["decode_MBps"],
            "source": f"{path}:codec_calibration",
        }
    return rec


def check(rec: dict) -> list[str]:
    """Contract failures of one drift record (empty = clean)."""
    errs = []
    ratios = rec.get("ratios")
    if ratios is None:
        return errs                     # no plan in the manifest (e.g. adamw)
    if ratios["wire_ratio"] != 1.0:
        errs.append(
            f"{rec['file']}: wire_ratio {ratios['wire_ratio']:.6g} != 1.0 "
            f"(predicted {rec['predicted']['wire_bytes']} B vs measured "
            f"{rec['measured']['wire_bytes_per_step']:.0f} B)")
    for name, v in ratios.items():
        if not math.isfinite(v):
            errs.append(f"{rec['file']}: {name} is not finite ({v})")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser(
        description="predicted-vs-measured planner drift report")
    ap.add_argument("paths", nargs="+",
                    help="telemetry JSONL files, or directories of them")
    ap.add_argument("--skip", type=int, default=1,
                    help="warmup steps excluded from the time medians "
                         "(step 0 carries compile; default 1)")
    ap.add_argument("--json", default="", help="write the full report to PATH")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every plan-bearing file has "
                         "wire_ratio exactly 1.0 and finite time ratios")
    args = ap.parse_args()

    files = []
    for p in args.paths:
        if os.path.isdir(p):
            files += sorted(os.path.join(p, f) for f in os.listdir(p)
                            if f.endswith(".jsonl"))
        else:
            files.append(p)
    if not files:
        print("report_drift: no telemetry files found", file=sys.stderr)
        return 2

    records, errors = [], []
    for path in files:
        rec = analyze(path, skip=args.skip)
        records.append(rec)
        errors += check(rec)
        name = rec.get("setting") or rec.get("config") or rec["file"]
        m = rec["measured"]
        if "ratios" in rec:
            r = rec["ratios"]
            print(f"{name:<24} wire_ratio {r['wire_ratio']:.3f} "
                  f"({rec['predicted']['wire_bytes']:,} B/step) "
                  f"comm/wall {r['comm_vs_wall']:.3g} "
                  f"ring/wall {r['ring_vs_wall']:.3g} "
                  f"overlap/wall {r['overlapped_vs_wall']:.3g} "
                  f"block/wall {m['block_vs_wall']:.3f}")
        else:
            print(f"{name:<24} (no comm_plan in manifest) "
                  f"wall {m['wall_s_median'] * 1e3:.1f} ms "
                  f"block/wall {m['block_vs_wall']:.3f}")
        if "calibration" in rec:
            c = rec["calibration"]
            print(f"{'':<24} calibration: encode "
                  f"{c['encode_MBps']:.0f} MB/s decode "
                  f"{c['decode_MBps']:.0f} MB/s "
                  f"(topology.overhead_from_telemetry ready)")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"records": records, "errors": errors}, f, indent=1)
        print(f"# wrote {args.json}")
    for e in errors:
        print(f"DRIFT: {e}", file=sys.stderr)
    if args.check and errors:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
