#!/usr/bin/env python
"""Perf-regression gate: compare a fresh ``benchmarks/run.py --json`` summary
against the committed baselines under ``experiments/bench/``.

Two classes of check, per benchmark row (rows are matched by their
``scheme`` / ``setting`` / ``name`` key, falling back to list position):

  * exact     -- every ``wire_bytes*`` field must match the baseline bit for
                 bit.  Wire bytes are STATIC functions of shapes and codec
                 plans; any drift is a silent wire-format regression (the
                 thing this repo exists to avoid), so there is no tolerance.
  * throughput-- every ``*_MBps`` field must stay above
                 ``tolerance * baseline``.  Timings are machine-dependent, so
                 the default tolerance only catches order-of-magnitude rot
                 (e.g. a codec that silently fell off the jit path).

Derived metrics embedded in a row (``max_err*`` fields) must also not grow
beyond ``--err-tol``.

Usage:
  python scripts/check_bench.py CURRENT.json [--baseline-dir experiments/bench]
                                [--throughput-tol 0.1] [--update]

``--update`` rewrites the baseline row sets from CURRENT.json instead of
comparing (how baselines are refreshed after an intentional wire change;
re-run ``benchmarks/run.py --only comms --json ...`` first).

Exit status: 0 = no regressions, 1 = at least one regression (printed),
2 = usage / missing file.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

EXACT_PREFIX = "wire_bytes"
THROUGHPUT_SUFFIX = "_MBps"
ERR_PREFIX = "max_err"


def _row_key(row: dict, i: int) -> str:
    for field in ("scheme", "setting", "name", "variant", "kernel"):
        if row.get(field) is not None:
            return f"{field}={row[field]}"
    return f"#{i}"


def _index_rows(name: str, rows: list, failures: list[str]) -> dict:
    out = {}
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            continue
        key = _row_key(row, i)
        if key in out:
            # a duplicate key would shadow one row from every check below —
            # exactly the silent drift the gate exists to catch
            failures.append(f"{name}[{key}]: duplicate row key; the bench "
                            f"must emit distinguishable rows")
        out[key] = row
    return out


def compare_rows(name: str, current: list, baseline: list,
                 throughput_tol: float, err_tol: float) -> list[str]:
    """All regressions of one benchmark's row set vs its baseline."""
    failures: list[str] = []
    cur = _index_rows(name, current, failures)
    base = _index_rows(name, baseline, failures)
    for key, brow in base.items():
        crow = cur.get(key)
        if crow is None:
            failures.append(f"{name}[{key}]: row disappeared from the bench")
            continue
        for field, bval in brow.items():
            cval = crow.get(field)
            if field.startswith(EXACT_PREFIX):
                if not isinstance(bval, (int, float)) \
                        or not isinstance(cval, (int, float)):
                    if bval != cval:
                        failures.append(
                            f"{name}[{key}].{field}: {bval!r} -> {cval!r} "
                            "(field absent or non-numeric in the current "
                            "run)")
                elif int(cval) != int(bval):
                    failures.append(
                        f"{name}[{key}].{field}: wire bytes changed "
                        f"{bval} -> {cval} (exact check; refresh baselines "
                        f"with --update if intentional)")
            elif field.endswith(THROUGHPUT_SUFFIX):
                if not isinstance(bval, (int, float)) or bval <= 0:
                    continue
                if not isinstance(cval, (int, float)) \
                        or cval < throughput_tol * bval:
                    failures.append(
                        f"{name}[{key}].{field}: throughput {cval} below "
                        f"{throughput_tol:g} x baseline {bval:.1f}")
            elif field.startswith(ERR_PREFIX):
                if isinstance(bval, (int, float)) and (
                        not isinstance(cval, (int, float))
                        or cval > max(float(bval), err_tol)):
                    failures.append(
                        f"{name}[{key}].{field}: error grew "
                        f"{bval} -> {cval} (tol {err_tol:g})")
    return failures


class CheckError(Exception):
    """Malformed input (usage error, exit 2) — never a traceback."""


def load_current(path: str) -> dict:
    """{bench name: rows} from a ``run.py --json`` summary (or a bare row
    set saved by ``run.py`` under experiments/bench/, keyed by filename)."""
    try:
        with open(path) as f:
            data = json.load(f)
    except OSError as e:
        raise CheckError(f"{path}: cannot read ({e})")
    except json.JSONDecodeError as e:
        raise CheckError(f"{path}: not valid JSON ({e})")
    if isinstance(data, dict) and "results" in data:
        out = {}
        for i, r in enumerate(data["results"]):
            if not isinstance(r, dict) or "name" not in r or "rows" not in r:
                raise CheckError(
                    f"{path}: results[{i}] lacks the 'name'/'rows' fields a "
                    "benchmarks/run.py --json summary always has")
            out[r["name"]] = r["rows"]
        return out
    name = os.path.splitext(os.path.basename(path))[0]
    return {name: data}


def run_check(current_path: str, baseline_dir: str, throughput_tol: float,
              err_tol: float, update: bool = False) -> list[str]:
    current = load_current(current_path)
    if not current:
        return [f"{current_path}: no benchmark results to check"]
    if update:
        os.makedirs(baseline_dir, exist_ok=True)
        for name, rows in current.items():
            with open(os.path.join(baseline_dir, f"{name}.json"), "w") as f:
                json.dump(rows, f, indent=1, default=str)
            print(f"updated baseline {name}.json ({len(rows)} rows)")
        return []
    failures = []
    checked = 0
    for name, rows in current.items():
        bpath = os.path.join(baseline_dir, f"{name}.json")
        if not os.path.exists(bpath):
            print(f"note: no baseline for {name!r} ({bpath}); skipping")
            continue
        try:
            with open(bpath) as f:
                baseline = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            failures.append(f"{name}: baseline {bpath} unreadable ({e}); "
                            "re-create it with --update")
            continue
        if not isinstance(baseline, list):
            failures.append(f"{name}: baseline {bpath} is not a row list; "
                            "re-create it with --update")
            continue
        failures += compare_rows(name, rows, baseline, throughput_tol,
                                 err_tol)
        checked += 1
    if checked == 0:
        failures.append(f"no baselines under {baseline_dir!r} matched "
                        f"{sorted(current)} — nothing was actually checked")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("current", help="summary written by benchmarks/run.py --json")
    ap.add_argument("--baseline-dir", default="experiments/bench")
    ap.add_argument("--throughput-tol", type=float, default=0.1,
                    help="current *_MBps must exceed TOL x baseline "
                    "(default 0.1: catches order-of-magnitude rot only)")
    ap.add_argument("--err-tol", type=float, default=1e-5,
                    help="absolute floor below which max_err growth is noise")
    ap.add_argument("--update", action="store_true",
                    help="rewrite baselines from CURRENT instead of comparing")
    args = ap.parse_args(argv)

    if not os.path.exists(args.current):
        print(f"error: {args.current} not found", file=sys.stderr)
        return 2
    try:
        failures = run_check(args.current, args.baseline_dir,
                             args.throughput_tol, args.err_tol, args.update)
    except CheckError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if failures:
        print(f"PERF REGRESSION: {len(failures)} check(s) failed")
        for f in failures:
            print(f"  FAIL {f}")
        return 1
    if not args.update:
        print("perf gate: OK (wire bytes exact, throughput within tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
