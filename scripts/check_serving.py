#!/usr/bin/env python
"""Serving-bench gate: compare a fresh ``benchmarks/run.py --only serving
--json`` summary against the committed ``experiments/bench/serving.json``.

Checks, per row (matched by ``setting``):

  * exact      -- ``requests`` / ``admitted`` / ``rejected`` / ``tokens`` /
                  ``compiles_after_warmup``.  The traffic trace is seeded and
                  EOS-free, so these are platform-independent counts; any
                  drift means the scheduler, the traffic stream, or the lane
                  pool changed behavior.  ``compiles_after_warmup`` must be
                  exactly 0 — the continuous-batching contract.
  * throughput -- ``tokens_per_s`` must stay above ``tol * baseline``
                  (machine-dependent; catches order-of-magnitude rot only).
  * latency    -- ``ttft_p50_ms`` / ``ttft_p99_ms`` / ``tok_p50_ms`` /
                  ``tok_p99_ms`` must stay below ``baseline / tol`` (same
                  slack, upper-bounded).
  * speedup    -- the CURRENT continuous row's ``speedup_vs_sequential``
                  must be >= ``--min-speedup`` (default 1.5).  Both sides of
                  the ratio ran on the same machine in the same process, so
                  unlike the timings it is NOT baseline-relative.

Usage:
  python scripts/check_serving.py CURRENT.json
      [--baseline experiments/bench/serving.json] [--tol 0.1]
      [--min-speedup 1.5] [--update]

``--update`` rewrites the baseline from CURRENT.json (after an intentional
traffic-mix or scheduler change; re-run ``benchmarks/run.py --only serving
--json ...`` first).

Exit status: 0 = gate passed, 1 = regression (printed), 2 = usage error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

EXACT = ("requests", "admitted", "rejected", "tokens",
         "compiles_after_warmup")
LATENCY = ("ttft_p50_ms", "ttft_p99_ms", "tok_p50_ms", "tok_p99_ms")


class CheckError(Exception):
    """Malformed input (usage error, exit 2) — never a traceback."""


def load_rows(path: str) -> list:
    """Serving rows from a run.py --json summary or a bare row list."""
    try:
        with open(path) as f:
            data = json.load(f)
    except OSError as e:
        raise CheckError(f"{path}: cannot read ({e})")
    except json.JSONDecodeError as e:
        raise CheckError(f"{path}: not valid JSON ({e})")
    if isinstance(data, dict) and "results" in data:
        for r in data["results"]:
            if isinstance(r, dict) and r.get("name") == "serving":
                return r["rows"]
        raise CheckError(f"{path}: no 'serving' entry in the summary — run "
                         "benchmarks/run.py --only serving --json PATH")
    if isinstance(data, list):
        return data
    raise CheckError(f"{path}: neither a run.py summary nor a row list")


def _index(rows: list, failures: list) -> dict:
    out = {}
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            continue
        key = str(row.get("setting", f"#{i}"))
        if key in out:
            failures.append(f"serving[{key}]: duplicate row key")
        out[key] = row
    return out


def compare(current: list, baseline: list, tol: float,
            min_speedup: float) -> list:
    failures: list = []
    cur = _index(current, failures)
    base = _index(baseline, failures)
    for key, brow in base.items():
        crow = cur.get(key)
        if crow is None:
            failures.append(f"serving[{key}]: row disappeared from the bench")
            continue
        for field in EXACT:
            bval, cval = brow.get(field), crow.get(field)
            if bval is None:
                continue
            if cval is None or int(cval) != int(bval):
                failures.append(
                    f"serving[{key}].{field}: exact count changed "
                    f"{bval} -> {cval} (seeded trace; refresh with --update "
                    "if the traffic mix changed intentionally)")
        bval, cval = brow.get("tokens_per_s"), crow.get("tokens_per_s")
        if isinstance(bval, (int, float)) and bval > 0:
            if not isinstance(cval, (int, float)) or cval < tol * bval:
                failures.append(
                    f"serving[{key}].tokens_per_s: {cval} below "
                    f"{tol:g} x baseline {bval:.1f}")
        for field in LATENCY:
            bval, cval = brow.get(field), crow.get(field)
            if not isinstance(bval, (int, float)) or bval <= 0:
                continue
            if not isinstance(cval, (int, float)) or cval > bval / tol:
                failures.append(
                    f"serving[{key}].{field}: latency {cval} above "
                    f"baseline {bval:.3f} / {tol:g}")
    crow = cur.get("continuous")
    if crow is None:
        failures.append("serving[continuous]: row missing from the bench")
    else:
        sp = crow.get("speedup_vs_sequential")
        if not isinstance(sp, (int, float)) or sp < min_speedup:
            failures.append(
                f"serving[continuous].speedup_vs_sequential: {sp} below the "
                f"required {min_speedup:g}x (continuous batching must beat "
                "naive sequential static batches)")
        if crow.get("compiles_after_warmup") != 0:
            failures.append(
                "serving[continuous].compiles_after_warmup: "
                f"{crow.get('compiles_after_warmup')} != 0 — the lane pool "
                "retraced under traffic")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("current",
                    help="summary written by benchmarks/run.py --json")
    ap.add_argument("--baseline", default="experiments/bench/serving.json")
    ap.add_argument("--tol", type=float, default=0.1,
                    help="tokens_per_s floor / latency ceiling factor vs "
                    "baseline (default 0.1: order-of-magnitude rot only)")
    ap.add_argument("--min-speedup", type=float, default=1.5,
                    help="required continuous/sequential tokens_per_s ratio "
                    "from the CURRENT run (same-machine, not vs baseline)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from CURRENT")
    args = ap.parse_args(argv)

    if not os.path.exists(args.current):
        print(f"error: {args.current} not found", file=sys.stderr)
        return 2
    try:
        rows = load_rows(args.current)
        if args.update:
            os.makedirs(os.path.dirname(args.baseline) or ".", exist_ok=True)
            with open(args.baseline, "w") as f:
                json.dump(rows, f, indent=1, default=str)
            print(f"updated baseline {args.baseline} ({len(rows)} rows)")
            return 0
        try:
            with open(args.baseline) as f:
                baseline = json.load(f)
        except OSError as e:
            raise CheckError(f"{args.baseline}: cannot read ({e}); create "
                             "it with --update")
        except json.JSONDecodeError as e:
            raise CheckError(f"{args.baseline}: not valid JSON ({e})")
        if not isinstance(baseline, list):
            raise CheckError(f"{args.baseline}: not a row list; re-create "
                             "with --update")
        failures = compare(rows, baseline, args.tol, args.min_speedup)
    except CheckError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if failures:
        print(f"SERVING REGRESSION: {len(failures)} check(s) failed")
        for f in failures:
            print(f"  FAIL {f}")
        return 1
    print("serving gate: OK (counts exact, zero recompiles, speedup and "
          "throughput within tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
