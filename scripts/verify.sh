#!/usr/bin/env bash
# Single verification entrypoint for builders and CI:
#   1. lint (ruff check, same rule set as the CI lint job; skipped with a
#      warning when ruff is not installed locally),
#   2. the tier-1 pytest suite (ROADMAP "Tier-1 verify" command),
#   3. the quick kernel microbench (Pallas-interpret vs jnp oracles),
#   4. the packed-vs-per-leaf extraction comparison (must stay bit-compatible),
#   5. a smoke run of the benchmark runner entrypoint (so benchmarks/run.py
#      and its imports can't silently rot between full bench runs),
#   6. the serving bench in smoke mode (continuous-batching lane pool vs the
#      sequential baseline; in-bench asserts pin zero recompiles after
#      warmup and equal token counts between the two schedulers).
# Usage: scripts/verify.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# lint FIRST (it is the cheapest failure): local runs must not discover lint
# breakage only when the CI lint job runs ruff
if command -v ruff >/dev/null 2>&1; then
  ruff check .
elif python -c "import ruff" >/dev/null 2>&1; then
  python -m ruff check .
else
  echo "verify: WARNING ruff not installed — lint runs only in CI" >&2
fi

python -m pytest -x -q "$@"

python - <<'EOF'
import sys
sys.path.insert(0, ".")
from benchmarks import bench_kernels, bench_packed

for row in bench_kernels.run():
    print(f"kernel {row['kernel']:>22}: max_err={row['max_err']:.2e}")
    assert row["max_err"] < 1e-3, row
rows = bench_packed.run()
for row in rows:
    print(f"packed {row['variant']:>16}: extract_calls={row['extract_calls']}"
          f" err={row['max_err_vs_per_leaf']:.2e}")
    assert row["max_err_vs_per_leaf"] < 1e-4, row
assert rows[1]["extract_calls"] == 1 and rows[0]["extract_calls"] > 1
print("verify: OK")
EOF

# BENCH_OUT: smoke-run row sets go to a scratch dir so the COMMITTED
# baselines under experiments/bench/ (the perf gate's reference — see
# scripts/check_bench.py) are never overwritten with 2-rep smoke timings.
BENCH_OUT="$(mktemp -d)" python benchmarks/run.py --only packed_extraction --smoke
BENCH_OUT="$(mktemp -d)" python benchmarks/run.py --only comms --smoke
BENCH_OUT="$(mktemp -d)" python benchmarks/run.py --only serving --smoke
