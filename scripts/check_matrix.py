#!/usr/bin/env python
"""Matrix gate: compare a fresh ``run_matrix.py`` results JSONL against the
committed smoke baseline (``experiments/matrix/smoke_baseline.json``).

Checks, per baseline cell (matched by content-addressed ``cell_id`` — the id
hashes the full normalized cell, so an edited sweep shows up as missing +
extra cells, never as a silent semantic change):

  * complete-or-skip -- every cell in the results must be ``ok`` or
                        ``skipped``; any ``error`` row fails the gate, and a
                        baseline cell with no current row fails (a sweep that
                        silently stopped short is not a pass).
  * skip stability   -- skipped cells must be skipped for the SAME reason;
                        the skip reasons mirror FlexConfig validation, so a
                        reason drift means the validation rules moved without
                        the compatibility predicate (or vice versa).
  * exact wire bytes -- ``wire_bytes_per_step`` on completed cells marked
                        ``wire_deterministic`` must match the baseline
                        exactly: wire formats are static functions of
                        shapes x codec, never timing.

Cells present in the results but absent from the baseline also fail — the
committed baseline IS the sweep's coverage contract; refresh it with
``--update`` when the spec intentionally changes:

  python scripts/run_matrix.py --spec experiments/matrix/smoke.json \
      --out /tmp/matrix/smoke.jsonl
  python scripts/check_matrix.py /tmp/matrix/smoke.jsonl --update
  git add experiments/matrix/smoke_baseline.json

Exit status: 0 = gate passed, 1 = at least one failure (printed),
2 = usage / missing or malformed input.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join("experiments", "matrix",
                                "smoke_baseline.json")

# the per-cell facts the baseline pins; everything else in a result row
# (losses, walls, plans) is measurement, gated elsewhere or not at all
BASELINE_FIELDS = ("cell_id", "status", "skip_reason", "wire_bytes_per_step",
                   "wire_deterministic", "workload", "scheme", "codec")


class CheckError(Exception):
    """Malformed input (usage error, exit 2) — never a traceback."""


def load_results(path: str) -> list[dict]:
    """Cell rows of a run_matrix.py results JSONL, LAST terminal row per
    cell_id winning (a resumed file legitimately contains an old error row
    followed by the successful re-run).  Torn trailing lines are skipped with
    the same tolerance as the runner's own resume."""
    try:
        with open(path) as f:
            lines = f.readlines()
    except OSError as e:
        raise CheckError(f"{path}: cannot read ({e})")
    rows: dict[str, dict] = {}
    saw_manifest = False
    for line in lines:
        line = line.strip()
        if not line:
            continue
        try:
            event = json.loads(line)
        except json.JSONDecodeError:
            continue
        if event.get("event") == "matrix_manifest":
            saw_manifest = True
        if event.get("event") != "cell" or not event.get("cell_id"):
            continue
        prev = rows.get(event["cell_id"])
        if prev is not None and prev.get("status") in ("ok", "skipped") \
                and event.get("status") == "error":
            continue                # never let a stale error shadow a result
        rows[event["cell_id"]] = event
    if not saw_manifest and not rows:
        raise CheckError(f"{path}: not a run_matrix.py results file "
                         "(no matrix_manifest or cell events)")
    return list(rows.values())


def _baseline_cell(row: dict) -> dict:
    return {k: row.get(k) for k in BASELINE_FIELDS if row.get(k) is not None}


def compare(rows: list[dict], baseline: dict) -> list[str]:
    failures: list[str] = []
    cur = {r["cell_id"]: r for r in rows}
    base = {c["cell_id"]: c for c in baseline.get("cells", [])}
    for r in rows:
        if r.get("status") == "error":
            err = str(r.get("error", ""))[:200]
            failures.append(f"{r['cell_id']}: error row — {err}")
    for cid, b in sorted(base.items()):
        c = cur.get(cid)
        if c is None:
            failures.append(f"{cid}: baseline cell missing from results — "
                            "the sweep stopped short or the spec changed "
                            "(refresh with --update if intentional)")
            continue
        if c.get("status") == "error":
            continue                # already reported above
        if c.get("status") != b.get("status"):
            failures.append(f"{cid}: status {b.get('status')!r} -> "
                            f"{c.get('status')!r}")
            continue
        if b.get("status") == "skipped" and \
                c.get("skip_reason") != b.get("skip_reason"):
            failures.append(
                f"{cid}: skip reason drifted {b.get('skip_reason')!r} -> "
                f"{c.get('skip_reason')!r} — compatibility predicate and "
                "FlexConfig validation moved apart?")
        if b.get("status") == "ok" and b.get("wire_deterministic"):
            bw, cw = b.get("wire_bytes_per_step"), \
                c.get("wire_bytes_per_step")
            if float(cw if cw is not None else -1.0) != \
                    float(bw if bw is not None else -1.0):
                failures.append(f"{cid}.wire_bytes_per_step: {bw} -> {cw} "
                                "(exact check — wire formats are static "
                                "functions of shapes x codec)")
    for cid in sorted(set(cur) - set(base)):
        failures.append(f"{cid}: cell not in the committed baseline — "
                        "refresh with --update if the spec change is "
                        "intentional")
    return failures


def run_check(results_path: str, baseline_path: str,
              update: bool = False) -> list[str]:
    rows = load_results(results_path)
    if update:
        cells = sorted((_baseline_cell(r) for r in rows
                        if r.get("status") in ("ok", "skipped")),
                       key=lambda c: c["cell_id"])
        errors = [r["cell_id"] for r in rows if r.get("status") == "error"]
        if errors:
            raise CheckError(
                f"refusing to bake error cells into the baseline: "
                f"{', '.join(errors)} — fix the sweep first")
        if not cells:
            raise CheckError(f"{results_path}: no terminal cells to commit")
        d = os.path.dirname(baseline_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(baseline_path, "w") as f:
            json.dump({"schema": 1, "cells": cells}, f, indent=1)
            f.write("\n")
        n_ok = sum(1 for c in cells if c["status"] == "ok")
        print(f"updated baseline {baseline_path} ({n_ok} ok, "
              f"{len(cells) - n_ok} skipped cells)")
        return []
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except OSError as e:
        raise CheckError(f"{baseline_path}: cannot read ({e}) — run the "
                         "sweep and commit a baseline via --update")
    except json.JSONDecodeError as e:
        raise CheckError(f"{baseline_path}: not valid JSON ({e})")
    if not baseline.get("cells"):
        raise CheckError(f"{baseline_path}: no cells — nothing would be "
                         "checked")
    return compare(rows, baseline)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("results", help="results JSONL written by run_matrix.py")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from RESULTS instead of "
                         "comparing")
    args = ap.parse_args(argv)

    if not os.path.exists(args.results):
        print(f"error: {args.results} not found", file=sys.stderr)
        return 2
    try:
        failures = run_check(args.results, args.baseline, args.update)
    except CheckError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if failures:
        print(f"MATRIX REGRESSION: {len(failures)} check(s) failed")
        for f in failures:
            print(f"  FAIL {f}")
        return 1
    if not args.update:
        print("matrix gate: OK (all cells complete-or-skip, skip reasons "
              "stable, wire bytes exact)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
