#!/usr/bin/env python
"""Experiment-matrix runner CLI: declarative sweeps, one subprocess per cell.

Parent mode drives a sweep spec — every cell in its own python process with
its own env (``XLA_FLAGS`` fake-device count, ``PYTHONPATH``), results
streaming one JSON line per cell into a resumable file:

  python scripts/run_matrix.py --spec experiments/matrix/smoke.json \
      --out /tmp/matrix/smoke.jsonl          # run every cell
  python scripts/run_matrix.py --spec experiments/matrix/smoke.json \
      --out /tmp/matrix/smoke.jsonl          # again: re-executes NOTHING
  python scripts/run_matrix.py --spec ... --dry-run     # enumerate + skip
                                                        # reasons, run nothing
  python scripts/run_matrix.py --spec ... --calibrate   # predicted-vs-
                                                        # measured roofline

Gate the output with ``scripts/check_matrix.py`` (no error rows, exact wire
bytes, stable skip reasons).  Refreshing the committed smoke baseline after
an INTENTIONAL sweep/validation change:

  python scripts/run_matrix.py --spec experiments/matrix/smoke.json \
      --out /tmp/matrix/smoke.jsonl
  python scripts/check_matrix.py /tmp/matrix/smoke.jsonl --update
  git add experiments/matrix/smoke_baseline.json

``--out`` defaults to $MATRIX_OUT falling back to
``/tmp/matrix/<spec-name>.jsonl`` — a scratch path, NOT a committed file
(the committed artifact is the check_matrix baseline, not raw results).

Child mode (``--cell``) is how the parent re-invokes this script per cell
(the torch_xla experiment_runner idiom): it pins the cell's fake-device
count into XLA_FLAGS BEFORE the first jax import, trains the cell through
the real shard_map step, and prints the result body as a marker-prefixed
final stdout line for the parent to parse.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _bootstrap_path() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def run_child(args) -> int:
    cell = json.loads(args.cell)
    # standalone-invocation safety: the parent's cell_env already pinned
    # XLA_FLAGS, but a hand-launched child must get the same topology
    devices = int(cell.get("devices", 0))
    if devices:
        from repro.launch import subproc

        os.environ["XLA_FLAGS"] = subproc.set_host_device_count(
            os.environ.get("XLA_FLAGS", ""), devices)
    from repro.experiments import matrix

    body = matrix.run_cell(cell, telemetry_out=args.telemetry_out,
                           log=lambda *a: print(*a, file=sys.stderr))
    print(matrix.RESULT_MARKER + json.dumps(body, default=str))
    return 0


def run_parent(args) -> int:
    from repro.experiments import matrix

    spec = matrix.load_spec(args.spec)
    out = args.out or os.path.join("/tmp", "matrix", f"{spec.name}.jsonl")
    if args.dry_run:
        done = matrix.completed_cells(matrix.read_results(out))
        for i, cell in enumerate(spec.cells):
            cid = matrix.cell_id(cell)
            reason = matrix.compatibility(cell)
            state = ("skip: " + reason if reason is not None else
                     "done" if cid in done else "run")
            print(f"{i + 1:3d}  {cid:<60} {state}")
        print(f"# {spec.name}: {len(spec.cells)} cells "
              f"({len(done)} already complete in {out})")
        return 0
    if args.calibrate:
        report = matrix.calibrate(out)
        print(json.dumps(report, indent=1, default=str))
        ov = report["codec_overhead"]
        if ov:
            print(f"# codec overhead: encode {ov['encode_s_per_byte']:.3e} "
                  f"s/B decode {ov['decode_s_per_byte']:.3e} s/B "
                  f"({ov['source']})", file=sys.stderr)
        return 0
    summary = matrix.run_sweep(
        spec, out, resume=not args.no_resume, max_cells=args.max_cells,
        telemetry_dir=args.telemetry_dir, timeout=args.timeout)
    return 1 if summary["errors"] else 0


def main() -> int:
    ap = argparse.ArgumentParser(
        description="experiment-matrix sweep runner (one subprocess per cell)")
    ap.add_argument("--spec", default="",
                    help="sweep spec JSON (see EXPERIMENTS.md)")
    ap.add_argument("--out", default=os.environ.get("MATRIX_OUT", ""),
                    help="results JSONL (default $MATRIX_OUT or "
                         "/tmp/matrix/<spec-name>.jsonl); appended on resume")
    ap.add_argument("--max-cells", type=int, default=0,
                    help="launch at most N cells this invocation (0 = all); "
                         "the rest defer to the next resume")
    ap.add_argument("--no-resume", action="store_true",
                    help="ignore + truncate any existing results file")
    ap.add_argument("--dry-run", action="store_true",
                    help="enumerate cells with skip/done/run state; run "
                         "nothing")
    ap.add_argument("--calibrate", action="store_true",
                    help="read completed results and print the predicted-vs-"
                         "measured roofline report + aggregated codec "
                         "overhead (topology.overhead_from_matrix)")
    ap.add_argument("--telemetry-dir",
                    default=os.environ.get("MATRIX_TELEMETRY", ""),
                    help="write one telemetry JSONL per cell into DIR "
                         "(default $MATRIX_TELEMETRY; empty = none)")
    ap.add_argument("--timeout", type=float, default=900.0,
                    help="per-cell subprocess timeout in seconds")
    ap.add_argument("--cell", default="",
                    help="(child mode) run ONE cell from its JSON and print "
                         "the marker-prefixed result line")
    ap.add_argument("--telemetry-out", default="",
                    help="(child mode) telemetry JSONL path for the cell")
    args = ap.parse_args()

    _bootstrap_path()
    if args.cell:
        return run_child(args)
    if not args.spec:
        ap.error("--spec is required (or --cell for child mode)")
    return run_parent(args)


if __name__ == "__main__":
    sys.exit(main())
