#!/usr/bin/env python
"""Run the seeded convergence-parity experiments (LM + ViT x all schemes).

Trains the reduced paper-domain workloads on a simulated 8-device mesh
(2x4 data x model) through the REAL shard_map train step and writes one
trajectory file per domain:

  python scripts/run_convergence.py                 # full runs -> committed
                                                    # experiments/convergence/
  python scripts/run_convergence.py --smoke \
      --out /tmp/conv_current                       # CI: short PREFIX runs,
                                                    # rows to a scratch dir

Gate the output with ``scripts/check_convergence.py`` (exact trajectory
prefixes where determinism is promised, tolerance bands and the paper-parity
criterion elsewhere).  Refreshing baselines after an INTENTIONAL optimizer
change:

  python scripts/run_convergence.py --out /tmp/conv_full
  python scripts/check_convergence.py /tmp/conv_full --update
  git add experiments/convergence/*.json

``--out`` defaults to $CONV_OUT, falling back to experiments/convergence
(the committed baseline dir) — CI MUST redirect it, mirroring BENCH_OUT.
"""
from __future__ import annotations

import argparse
import os
import sys


def main() -> int:
    ap = argparse.ArgumentParser(
        description="seeded convergence-parity experiment runner")
    ap.add_argument("--domains", default="lm,vit",
                    help="comma-separated subset of: lm, vit")
    ap.add_argument("--settings", default="",
                    help="run only settings whose name contains SUBSTR")
    ap.add_argument("--smoke", action="store_true",
                    help="short-step-budget runs (a strict PREFIX of the "
                         "full trajectory; the gate compares the overlap)")
    ap.add_argument("--out", default=os.environ.get("CONV_OUT", ""),
                    help="output dir (default $CONV_OUT or "
                         "experiments/convergence)")
    ap.add_argument("--mesh", default="2x4", help="DxM (data x model)")
    ap.add_argument("--telemetry-out",
                    default=os.environ.get("CONV_TELEMETRY", ""),
                    help="also write one telemetry JSONL per (domain x "
                         "setting) run into DIR (default $CONV_TELEMETRY; "
                         "empty = no telemetry). Rows are unchanged; feed "
                         "the JSONLs to scripts/report_drift.py")
    ap.add_argument("--devices", type=int, default=8,
                    help="fake host devices to force BEFORE importing jax "
                         "(0 = leave XLA_FLAGS alone)")
    args = ap.parse_args()

    if args.devices:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.experiments import convergence

    d, m = (int(x) for x in args.mesh.split("x"))
    out_dir = args.out or convergence.DEFAULT_OUT
    for domain in [s for s in args.domains.split(",") if s]:
        data = convergence.run_domain(
            domain, mesh_shape=(d, m), smoke=args.smoke,
            settings_filter=args.settings,
            telemetry_dir=args.telemetry_out)
        path = convergence.save_domain(data, out_dir)
        rows = data["rows"]
        ref = next((r for r in rows if r["reference"]), None)
        for r in rows:
            vs = (f" vs_ref {r['final_val_ratio_vs_ref']:.3f}"
                  if ref is not None else "")
            print(f"{domain:>4}/{r['setting']:<18} "
                  f"train {r['final_train']:.4f} val {r['final_val']:.4f}"
                  f"{vs} wire {r['wire_bytes_per_step']:,.0f}B/step")
        print(f"# wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
